"""Serving policy: deadlines, retry, backpressure, graceful degradation.

:class:`ServePolicy` is the engine's request-lifecycle contract under
stress (docs/robustness.md):

  * **Deadlines** — per-request e2e and TTFT deadlines (policy defaults,
    overridable per ``submit``).  A request past its deadline terminates
    with status ``"deadline"`` whether queued or active; it is never
    silently dropped.
  * **Retry** — a guard-tripped request is rewound to a fresh admission
    and requeued (front of queue) behind a capped exponential backoff;
    after ``max_retries`` requeues it terminates with status ``"failed"``.
  * **Backpressure** — queue-length and queue-age caps.  Overflow triggers
    *graceful degradation first*: when ``brownout`` is on and a QoS
    controller with remaining ladder rungs is attached, the engine forces
    the controller one rung DOWN the calibrated ``ApproxPlan`` ladder
    (cheaper approximate arithmetic -> faster ticks -> the queue drains)
    and only sheds — status ``"shed"``, newest first — once the ladder is
    exhausted.  This is the dissertation's runtime-adjustable approximation
    as a quality-management loop: under overload the server degrades
    *quality*, not *availability*.

Everything here measures time through the engine's injectable clock, so
:class:`VirtualClock` makes deadline/backoff/goodput behavior fully
deterministic for tests and the chaos benchmark.

:func:`retry` is the shared host-side I/O retry helper (satellite of the
same PR): used for dataset file loads (``data/pipeline.py``) and bench
record writes (``benchmarks/run.py``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional


@dataclass
class ServePolicy:
    """Engine policy knobs; any field None/0 disables that mechanism."""

    #: default per-request e2e deadline (ms, from enqueue; None = none)
    deadline_ms: Optional[float] = None
    #: default per-request TTFT deadline (ms, enqueue -> first emission)
    ttft_deadline_ms: Optional[float] = None
    #: guard-trip requeues before a request fails
    max_retries: int = 2
    #: retry backoff: base * 2**(retries-1), capped (ms)
    backoff_ms: float = 1.0
    backoff_cap_ms: float = 50.0
    #: queue-length backpressure cap (None = unbounded)
    max_queue: Optional[int] = None
    #: queue-age backpressure: shed requests older than this (ms) that are
    #: still waiting (independent of their own deadline)
    max_queue_age_ms: Optional[float] = None
    #: degrade down the QoS ladder before shedding (needs qos= on engine)
    brownout: bool = True
    #: modeled per-admission-call latency (ms) for the doomed-request
    #: check: a queued request whose remaining TTFT budget cannot cover
    #: ``workload.admit_calls(req) * admit_eta_ms`` is shed early
    #: (status "shed", reason "doomed") instead of burning device calls
    #: on an admission that must miss (None = check disabled)
    admit_eta_ms: Optional[float] = None

    def backoff_s(self, retries: int) -> float:
        """Capped exponential backoff (seconds) before retry #``retries``."""
        return min(self.backoff_cap_ms,
                   self.backoff_ms * (2 ** max(0, retries - 1))) / 1e3


class VirtualClock:
    """Deterministic manual clock: callable like ``time.time`` (pass as
    ``ServeCore(clock=...)``) plus ``advance``.  The chaos benchmark drives
    it by the modeled per-rung tick cost (``tune.autotune.vector_cost``),
    so deadline/goodput numbers are exact functions of the schedule — and
    brownout's cheaper rungs genuinely drain the queue faster even on CPU
    emulation, where wall-clock per tick wouldn't move with the degree."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


def retry(fn, *, attempts: int = 3, backoff: float = 0.05,
          cap: float = 1.0, exceptions=(OSError,), sleep=time.sleep):
    """Call ``fn()`` with capped-exponential-backoff retries on transient
    host-side failures.  Re-raises the last exception once ``attempts``
    are exhausted; non-matching exceptions propagate immediately."""
    if attempts < 1:
        raise ValueError("retry needs attempts >= 1")
    for i in range(attempts):
        try:
            return fn()
        except exceptions:
            if i == attempts - 1:
                raise
            sleep(min(cap, backoff * (2 ** i)))
