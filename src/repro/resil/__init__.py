"""repro.resil — fault injection, runtime guards, graceful degradation.

The dissertation hardens DSP kernels for space-grade (radiation-exposed)
FPGAs and proposes runtime-adjustable approximation as a low-overhead
quality-management loop.  This package is that story at system level
(DESIGN.md §13): a serving stack that *expects* faults —

  * :mod:`repro.resil.faults`  — deterministic, seeded SEU-style fault
    injection (bit flips into params / per-slot cache state, NaN/Inf into
    activations, latency spikes, dropped ticks);
  * :mod:`repro.resil.guards`  — jit-safe per-slot output guards, golden
    param scrubbing, and a quality-tap anomaly sentinel;
  * :mod:`repro.resil.policy`  — per-request deadlines, capped-backoff
    retry, queue backpressure, and brownout-by-approximation: under
    overload the QoS controller is forced down the calibrated
    ``ApproxPlan`` ladder *before* any request is shed.

All three wire through ``serve/engine.py::ServeCore`` for every workload
(LM and stream alike) and are fully instrumented in ``repro.obs``.
"""

from repro.resil.faults import FaultEvent, FaultPlan, FaultSpec
from repro.resil.guards import GuardConfig, QualitySentinel, slot_ok
from repro.resil.policy import ServePolicy, VirtualClock, retry

__all__ = [
    "FaultEvent", "FaultPlan", "FaultSpec",
    "GuardConfig", "QualitySentinel", "slot_ok",
    "ServePolicy", "VirtualClock", "retry",
]
