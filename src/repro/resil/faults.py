"""Deterministic, seeded fault injection for the serve engine.

Fault model (docs/robustness.md): the dissertation's deployment target is
space-grade FPGAs where radiation-induced single-event upsets (SEUs) flip
bits in configuration and user memory; the standard mitigations are memory
scrubbing and architectural masking.  We model the software-visible end of
that spectrum against the serving stack:

  * ``seu_state``  — flip one bit inside one slot's region of one decode
    state field (KV ring, recurrent state, conv tail) via
    :func:`repro.models.cache_ops.cache_bit_flip`;
  * ``seu_param``  — flip one bit of one weight leaf (persistent until the
    engine scrubs back to its golden copy);
  * ``nan``        — corrupt one slot's activations with NaN/Inf inside the
    fused step, through the traced ``fault`` operand consumed by
    ``dispatch.inject_fault``;
  * ``spike``      — a latency spike in the engine loop (the engine stalls
    its clock);
  * ``drop``       — a dropped tick: the fused step is skipped outright
    (no state advance, no emissions, no budget charged);
  * ``replica_loss`` — a whole serving replica dies (fleet level: the
    :class:`~repro.dist.fleet.FleetSupervisor` marks it dead, rewinds its
    in-flight requests onto the survivors, and replans the mesh through
    ``dist.elastic``; single-engine plans ignore the kind).

Determinism contract: :meth:`FaultPlan.events_at` derives every draw from
``np.random.default_rng((seed, tick))`` — stateless per tick, so the same
``--fault-seed`` yields an identical injected-fault sequence regardless of
how many ticks actually run, in what order engines are constructed, or
whether a run is resumed.  SEU bit choice is biased to the high-order
magnitude bit (``seu_bit=-2``: top exponent bit for floats, bit 30 for
int32) — the worst-case upset, and the one runtime guards can be expected
to catch; pass ``seu_bit="uniform"`` for a uniform-bit model.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.models import cache_ops

#: spec-string aliases accepted by :meth:`FaultSpec.parse`
_ALIASES = {
    "seu": "seu_state", "seu_state": "seu_state", "state": "seu_state",
    "seu_param": "seu_param", "param": "seu_param",
    "nan": "nan", "inf": "nan",
    "spike": "spike", "latency": "spike",
    "drop": "drop", "drop_tick": "drop",
    "replica": "replica_loss", "replica_loss": "replica_loss",
}


@dataclass(frozen=True)
class FaultSpec:
    """Per-tick fault probabilities (independent Bernoulli draws per kind).

    ``spike_ms`` is the stall a latency spike adds; ``inf_ratio`` the share
    of activation faults injected as Inf instead of NaN; ``seu_bit`` the
    bit targeted by SEU flips (negative = from the top: -2 is the high
    magnitude bit, see module docstring; "uniform" draws uniformly)."""

    seu_state: float = 0.0
    seu_param: float = 0.0
    nan: float = 0.0
    spike: float = 0.0
    drop: float = 0.0
    #: whole-replica loss (fleet-level; consumed by dist/fleet.py — engines
    #: ignore the kind).  Needs :meth:`FaultPlan.bind_fleet` for a victim.
    replica_loss: float = 0.0
    spike_ms: float = 5.0
    inf_ratio: float = 0.5
    seu_bit: object = -2

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Parse a ``--faults`` flag string: ``"seu=0.05,nan=0.1,drop=0.01"``
        (aliases: seu/state -> seu_state, param -> seu_param, inf -> nan,
        latency -> spike, replica -> replica_loss).  ``spike_ms``/
        ``inf_ratio``/``seu_bit`` may ride along by their field names."""
        kw = {}
        for part in filter(None, (p.strip() for p in text.split(","))):
            key, _, val = part.partition("=")
            if not val:
                raise ValueError(f"bad --faults entry {part!r} (want k=v)")
            key = key.strip()
            if key in _ALIASES:
                kw[_ALIASES[key]] = float(val)
            elif key in ("spike_ms", "inf_ratio"):
                kw[key] = float(val)
            elif key == "seu_bit":
                kw[key] = val if val == "uniform" else int(val)
            else:
                raise ValueError(f"unknown fault kind {key!r} "
                                 f"(know: {sorted(set(_ALIASES))})")
        return cls(**kw)


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault.  ``kind`` is a FaultSpec rate name; the target
    fields that apply depend on the kind (slot/field/index/bit for state
    SEUs, leaf/index/bit for param SEUs, slot/value for activation faults,
    value=stall-seconds for spikes)."""

    tick: int
    kind: str
    slot: Optional[int] = None
    target: Optional[str] = None   # state field name | param leaf path
    leaf: Optional[int] = None     # param leaf index (tree flatten order)
    index: Optional[int] = None    # flat element offset within the region
    bit: Optional[int] = None
    value: Optional[float] = None  # NaN/Inf payload or spike seconds

    def args(self) -> dict:
        """Trace-event / recovery-log args (deterministic, JSON-safe)."""
        out = {"kind": self.kind}
        for k in ("slot", "target", "leaf", "index", "bit"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        if self.value is not None:
            out["value"] = repr(float(self.value))
        return out


class FaultPlan:
    """Seeded fault schedule over engine ticks.

    Stochastic mode: pass a :class:`FaultSpec` and a seed; each tick's
    events come from a stateless per-tick RNG (see module docstring).
    Scripted mode: pass explicit ``events`` for exact-scenario tests.
    The engine calls :meth:`bind` once (captures state-field / param-leaf
    shapes so draws can pick targets) and :meth:`events_at` per tick;
    every event actually applied lands in ``injected`` — the injected-fault
    sequence the determinism tests assert on.
    """

    def __init__(self, spec: Optional[FaultSpec] = None, *, seed: int = 0,
                 events: Optional[list] = None):
        if spec is None and events is None:
            raise ValueError("FaultPlan needs a FaultSpec or scripted events")
        self.spec = spec
        self.seed = int(seed)
        self._scripted = list(events) if events is not None else None
        self.injected: list[FaultEvent] = []
        self._fields: list[tuple[str, int, int]] = []   # (name, numel/slot, bits)
        self._leaves: list[tuple[str, int, int]] = []   # (path, numel, bits)
        self._slots = 0
        self._replicas = 0

    # -- binding ---------------------------------------------------------
    def bind(self, state, params, slots: int) -> "FaultPlan":
        """Capture the fault surface: per-slot region size of every state
        field (``length`` excluded — flipping the scheduler cursor is a
        control fault, not a memory fault) and every param leaf."""
        self._slots = int(slots)
        self._fields = []
        for name in state._fields:
            if name == "length":
                continue
            o = getattr(state, name)
            numel = int(np.prod(o.shape) // o.shape[1])  # batch at axis 1
            self._fields.append((name, numel, 8 * o.dtype.itemsize))
        self._leaves = []
        leaves, _ = jax.tree_util.tree_flatten(params)
        for i, leaf in enumerate(leaves):
            numel = int(np.prod(np.shape(leaf)))
            if numel:
                self._leaves.append((str(i), numel,
                                     8 * np.asarray(leaf).dtype.itemsize))
        return self

    def bind_fleet(self, replicas: int) -> "FaultPlan":
        """Capture the fleet fault surface: ``replica_loss`` draws pick a
        victim in ``[0, replicas)``.  Orthogonal to :meth:`bind` — a
        fleet-level plan usually binds only this."""
        self._replicas = int(replicas)
        return self

    # -- schedule --------------------------------------------------------
    def _bit(self, rng, bits: int) -> int:
        sb = self.spec.seu_bit
        if sb == "uniform":
            return int(rng.integers(bits))
        return bits + sb if sb < 0 else min(sb, bits - 1)

    def events_at(self, tick: int) -> list[FaultEvent]:
        """The faults scheduled for ``tick`` (deterministic; see class
        docstring).  Draw order is fixed per kind so the sequence only
        depends on (seed, tick, bound shapes)."""
        if self._scripted is not None:
            return [ev for ev in self._scripted if ev.tick == tick]
        sp = self.spec
        rng = np.random.default_rng((self.seed, tick))
        out: list[FaultEvent] = []
        if rng.random() < sp.seu_state and self._fields:
            name, numel, bits = self._fields[int(rng.integers(len(self._fields)))]
            out.append(FaultEvent(
                tick, "seu_state", slot=int(rng.integers(self._slots)),
                target=name, index=int(rng.integers(numel)),
                bit=self._bit(rng, bits)))
        if rng.random() < sp.seu_param and self._leaves:
            li = int(rng.integers(len(self._leaves)))
            path, numel, bits = self._leaves[li]
            out.append(FaultEvent(
                tick, "seu_param", leaf=li, target=path,
                index=int(rng.integers(numel)), bit=self._bit(rng, bits)))
        if rng.random() < sp.nan:
            val = np.inf if rng.random() < sp.inf_ratio else np.nan
            out.append(FaultEvent(tick, "nan",
                                  slot=int(rng.integers(self._slots)),
                                  value=float(val)))
        if rng.random() < sp.spike:
            out.append(FaultEvent(tick, "spike", value=sp.spike_ms / 1e3))
        if rng.random() < sp.drop:
            out.append(FaultEvent(tick, "drop"))
        # replica_loss draws are gated on the rate being nonzero so plans
        # written before the kind existed keep their exact RNG sequences
        # (an unconditional draw would shift every later kind's stream)
        if sp.replica_loss and rng.random() < sp.replica_loss \
                and self._replicas:
            out.append(FaultEvent(
                tick, "replica_loss", target="replica",
                slot=int(rng.integers(self._replicas))))
        return out

    # -- application helpers (host-side; eager jnp ops) -------------------
    def apply_state(self, state, ev: FaultEvent):
        """Flip the state bit ``ev`` names (returns a new state tuple)."""
        return cache_ops.cache_bit_flip(state, ev.target, ev.slot,
                                        ev.index, ev.bit)

    def apply_params(self, params, ev: FaultEvent):
        """Flip the param bit ``ev`` names (returns a new tree; the old
        tree — the engine's golden copy — is untouched)."""
        leaves, treedef = jax.tree_util.tree_flatten(params)
        leaves[ev.leaf] = cache_ops.bit_flip(leaves[ev.leaf], ev.index, ev.bit)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def record(self, ev: FaultEvent) -> FaultEvent:
        self.injected.append(ev)
        return ev
