"""Jit-safe runtime guards for the serve engine's fused step.

Two detection layers (docs/robustness.md):

  * **Per-slot output guards** — every guarded step returns a per-slot
    ``ok`` bool alongside its emission: :func:`slot_ok` checks the slot's
    output activation is finite everywhere and (when the workload declares
    a ``guard_limit``) within its magnitude bound — LM logits within
    ``|x| <= limit``, stream frames within the Q-format range the clean
    int pipeline can never leave.  The check runs *inside* the compiled
    step (one fused reduction, no host sync beyond the ok vector), so a
    corrupted emission is never banked: the engine quarantines the slot —
    resets it through the bit-identical ``cache_ops`` reset — and requeues
    or fails the request per policy.

  * **Quality-anomaly sentinel** — :class:`QualitySentinel` watches the
    live-vs-exact samples the engine's quality tap (``obs/quality.py``)
    already produces and trips when ``window`` consecutive samples cross
    the threshold (logit-RMS above, or PSNR-dB below, per ``mode``): the
    value-corruption analogue of the slot guards, catching drift the
    finite/range checks can't see.

On any trip the engine *scrubs*: it rebinds its golden parameter tree
(JAX immutability makes the golden copy a free reference), repairing
persistent ``seu_param`` corruption — the software analogue of the
configuration-memory scrubbing the dissertation's rad-hard FPGA targets
rely on.  ``scrub_every`` adds blind periodic scrubbing on top.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp


def slot_ok(x, *, limit: Optional[float] = None):
    """Per-slot sanity reduction over a (slots, ...) activation batch:
    True where the slot's values are all finite and, when ``limit`` is
    given, all within ``|x| <= limit``.  Jit-safe; NaN compares unordered
    so a NaN fails the limit check too."""
    red = tuple(range(1, x.ndim))
    if jnp.issubdtype(x.dtype, jnp.floating):
        ok = jnp.all(jnp.isfinite(x), axis=red)
    else:
        ok = jnp.ones((x.shape[0],), bool)
    if limit is not None:
        bound = jnp.asarray(limit, jnp.float32)
        ok = ok & jnp.all(jnp.abs(x.astype(jnp.float32)) <= bound, axis=red)
    return ok


@dataclass
class GuardConfig:
    """Engine guard knobs.  Passing a GuardConfig (or any fault plan) to
    ``ServeCore`` switches it onto the workload's ``guarded_step`` — same
    arithmetic, plus the traced fault operand and the per-slot ok bits."""

    #: override the workload's ``guard_limit`` magnitude bound (None keeps
    #: the workload default: 1e4 for LM logits, 2 << q for stream frames)
    limit: Optional[float] = None
    #: restore the golden param tree whenever any guard trips
    scrub_on_trip: bool = True
    #: blind periodic scrub every N ticks (0 = off)
    scrub_every: int = 0
    #: quality-tap anomaly threshold (None = sentinel off; needs
    #: ``quality_every > 0`` on the engine)
    sentinel_threshold: Optional[float] = None
    #: "max": trip when sample > threshold (error metrics, LM logit RMS);
    #: "min": trip when sample < threshold (fidelity metrics, stream PSNR)
    sentinel_mode: str = "max"
    #: consecutive bad samples required to trip
    sentinel_window: int = 1

    def sentinel(self) -> Optional["QualitySentinel"]:
        if self.sentinel_threshold is None:
            return None
        return QualitySentinel(self.sentinel_threshold,
                               mode=self.sentinel_mode,
                               window=self.sentinel_window)


class QualitySentinel:
    """Threshold watcher over the quality tap's live-vs-exact samples."""

    def __init__(self, threshold: float, *, mode: str = "max",
                 window: int = 1):
        if mode not in ("max", "min"):
            raise ValueError(f"sentinel mode {mode!r} (want max|min)")
        self.threshold = float(threshold)
        self.mode = mode
        self.window = max(1, int(window))
        self._bad = 0
        self.trips = 0

    def observe(self, value: float) -> bool:
        """Feed one sample; True when the trip condition fires (resets the
        consecutive-bad counter so one anomaly reports once)."""
        bad = (value > self.threshold if self.mode == "max"
               else value < self.threshold)
        self._bad = self._bad + 1 if bad else 0
        if self._bad >= self.window:
            self._bad = 0
            self.trips += 1
            return True
        return False
