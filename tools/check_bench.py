"""Bench-record regression gate (CI `bench-regress` job).

Thin CLI over :mod:`repro.obs.regress`.  Two modes:

* default — validate every committed ``benchmarks/BENCH_*.json``
  record (``repro.obs.regress.BENCH_RECORDS``): schema-v2 meta stamp
  (git SHA, platform, JAX + kernel backends) plus each bench's declared
  scale-invariant invariants (error envelopes, skip-grid step ratios,
  fused-GEMM speedup floors, planned-ladder Pareto order, chaos
  brownout-dominance/containment/accounting).  Catches hand-edits,
  rotted rows, and regenerations that silently regressed a claim.
* ``--fresh`` — additionally re-run the bench modules in-process (tiny
  shapes when ``REPRO_BENCH_TINY=1`` is exported, as CI does) and
  require every fresh row name to exist in the committed record and the
  fresh record to satisfy the same invariants.  Raw timings are never
  diffed across machines — only the declared invariants are portable.

Exit code 0 = all records healthy; non-zero prints every violation.

  PYTHONPATH=src python tools/check_bench.py
  REPRO_BENCH_TINY=1 PYTHONPATH=src python tools/check_bench.py --fresh
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(ROOT), str(ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.obs import regress  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", action="append", default=None,
                    choices=sorted(regress.BENCH_RECORDS),
                    help="check only this bench (repeatable; default: all)")
    ap.add_argument("--fresh", action="store_true",
                    help="also re-run the benches and diff against the "
                         "committed records (tiny shapes when "
                         "REPRO_BENCH_TINY=1 is exported)")
    args = ap.parse_args(argv)

    benches = args.bench or sorted(regress.BENCH_RECORDS)
    errs = regress.check_committed(benches=benches)
    for e in errs:
        print(f"[check_bench] FAIL {e}")

    if args.fresh and not errs:
        tiny = os.environ.get("REPRO_BENCH_TINY", "0") == "1"
        from benchmarks.run import make_record

        for bench in benches:
            committed = regress.load_record(bench)
            print(f"[check_bench] fresh run: {bench} "
                  f"({'tiny' if tiny else 'full'} shapes) ...", flush=True)
            fresh = make_record(bench, regress.run_fresh_rows(bench))
            found = regress.compare_fresh(committed, fresh)
            for e in found:
                print(f"[check_bench] FAIL {e}")
            errs.extend(found)

    n = len(benches)
    if errs:
        print(f"[check_bench] {len(errs)} violation(s) across {n} record(s)")
        return 1
    mode = "committed+fresh" if args.fresh else "committed"
    print(f"[check_bench] OK — {n} record(s) pass ({mode})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
