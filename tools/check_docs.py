"""Docs health check (CI `docs` job; also run by tests/test_docs.py).

Two gates, stdlib-only so the job needs no installs:

1. **intra-repo links** — every relative markdown link in README.md,
   DESIGN.md, ROADMAP.md and docs/*.md must resolve to a file or directory
   in the repo (anchors stripped; http(s)/mailto links skipped).
2. **doc snippets** — every fenced ``python`` block in docs/*.md must at
   least compile (`compile(..., "exec")` — the compileall-style gate), so
   examples can't rot into syntax errors silently.  Blocks marked with a
   ``# doctest: skip`` first line are exempt (e.g. deliberately elided
   fragments).

Exit code 0 = healthy; non-zero prints every violation.

  python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", "DESIGN.md", "ROADMAP.md"]
SNIPPET_DIRS = ["docs"]

_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_EXTERNAL = ("http://", "https://", "mailto:")


def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.relative_to(ROOT))
    except ValueError:            # outside the repo (tests use tmp files)
        return str(path)


def doc_paths() -> list:
    out = [ROOT / f for f in DOC_FILES if (ROOT / f).exists()]
    for d in SNIPPET_DIRS:
        out.extend(sorted((ROOT / d).glob("*.md")))
    return out


def check_links(path: pathlib.Path) -> list:
    """Return broken-link messages for one markdown file."""
    errors = []
    for target in _LINK.findall(path.read_text()):
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"{_rel(path)}: broken link -> {target}")
    return errors


def check_snippets(path: pathlib.Path) -> list:
    """Return compile-failure messages for one markdown file's ```python
    fences."""
    errors = []
    for i, block in enumerate(_FENCE.findall(path.read_text())):
        if block.lstrip().startswith("# doctest: skip"):
            continue
        try:
            compile(block, f"{path.name}[snippet {i}]", "exec")
        except SyntaxError as e:
            errors.append(f"{_rel(path)} snippet {i}: {e}")
    return errors


def run() -> list:
    errors = []
    snippet_files = []
    for d in SNIPPET_DIRS:
        snippet_files.extend(sorted((ROOT / d).glob("*.md")))
    for p in doc_paths():
        errors.extend(check_links(p))
    for p in snippet_files:
        errors.extend(check_snippets(p))
    return errors


def main() -> int:
    errors = run()
    for e in errors:
        print(f"[check_docs] {e}", file=sys.stderr)
    n_docs = len(doc_paths())
    if errors:
        print(f"[check_docs] FAILED: {len(errors)} problem(s) in {n_docs} "
              f"file(s)", file=sys.stderr)
        return 1
    print(f"[check_docs] OK: {n_docs} markdown file(s), links + snippets")
    return 0


if __name__ == "__main__":
    sys.exit(main())
